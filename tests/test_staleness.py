"""Staleness timeline tests: the paper's worked example (Fig. 1) plus a
seeded randomized property sweep over (tau, T_c, T_p) — monotonicity,
the t <= tau+1 reference boundary, and the ordering of the master's
update time vs the workers' receive time. (Plain numpy randomness, not
hypothesis: the sweep must run on images without it.)

Plus the zero-arrival staleness contract of the delay-ADAPTIVE step
size: stall steps must report (and step with) the ring-cap fallback
staleness, never tau = 0 — pinned by a seeded regression at the full
ambdg-strategy level."""
import math

import numpy as np
import pytest

from repro.core.staleness import (Timeline, amb_epoch_duration,
                                  ambdg_epoch_duration,
                                  gradient_reference_epoch,
                                  master_update_time, staleness,
                                  worker_receives_update_at)


def test_tau_definition():
    assert staleness(10.0, 2.5) == 4
    assert staleness(7.5, 2.5) == 3
    assert staleness(8.0, 2.5) == 4      # ceil
    assert staleness(0.0, 2.5) == 0


def test_paper_fig1_example():
    """T_c = 3 T_p => tau = 3; gradients for epochs 1..4 use w(1);
    the master's 6th update uses gradients w.r.t. w(2) (staleness 3)."""
    tau = staleness(7.5, 2.5)
    assert tau == 3
    for t in (1, 2, 3, 4):
        assert gradient_reference_epoch(t, tau) == 1
    assert gradient_reference_epoch(5, tau) == 2   # w(6) <- grads at w(2)
    assert gradient_reference_epoch(9, tau) == 6


def test_update_times():
    tl = Timeline(t_p=2.5, t_c=10.0)
    assert tl.tau == 4
    # paper Sec. VI-A: AMB-DG updates every T_p = 2.5 s, first at 7.5 s;
    # AMB every T_p + T_c = 12.5 s
    assert tl.epochs_until(7.5, "ambdg") == 1
    assert tl.epochs_until(9.9, "ambdg") == 1
    assert tl.epochs_until(10.0, "ambdg") == 2
    assert tl.epochs_until(7.5, "amb") == 1
    assert tl.epochs_until(19.9, "amb") == 1
    assert tl.epochs_until(20.0, "amb") == 2


def test_epoch_durations_converge_when_tc_zero():
    """As T_c -> 0, AMB-DG reduces to AMB (paper Sec. VI-A.4)."""
    tl = Timeline(t_p=2.5, t_c=0.0)
    assert tl.tau == 0
    assert tl.epochs_until(25.0, "ambdg") == tl.epochs_until(25.0, "amb")


def test_paper_worked_example_via_timeline():
    """T_c = 3*T_p => tau = 3; w(6) is computed from gradients w.r.t.
    w(2) — the paper's Sec. III worked example, through the Timeline
    bundle the simulator and launcher actually use."""
    tl = Timeline(t_p=2.5, t_c=7.5)
    assert tl.tau == 3
    # w(t+1) comes from the master's t-th update; w(6) <- update t=5,
    # whose gradients were computed w.r.t. w(reference(5)) = w(2)
    assert tl.reference(5) == 2
    # every epoch in the fill phase references w(1)
    assert [tl.reference(t) for t in (1, 2, 3, 4)] == [1, 1, 1, 1]
    # AMB-DG epochs tile at T_p; AMB pays the round trip every epoch
    assert ambdg_epoch_duration(2.5, 7.5) == 2.5
    assert amb_epoch_duration(2.5, 7.5) == 10.0


def test_staleness_property_sweep():
    """Randomized (tau, T_c, T_p) sweep of the timeline algebra:

      * tau = ceil(T_c/T_p) bracketing: (tau-1)*T_p < T_c <= tau*T_p
      * gradient_reference_epoch is monotone non-decreasing in t, with
        the paper's boundary: r = 1 iff t <= tau+1, else r = t - tau
        (so staleness saturates at exactly tau after the fill phase)
      * the master's t-th update happens before workers receive
        w(t+1), and both sequences strictly increase
      * epochs_until inverts master_update_time for AMB-DG
    """
    rng = np.random.default_rng(0)
    for _ in range(300):
        t_p = float(rng.uniform(0.1, 10.0))
        t_c = float(rng.uniform(0.0, 50.0))
        tau = staleness(t_c, t_p)
        assert tau == math.ceil(t_c / t_p)
        assert (tau - 1) * t_p < t_c or t_c == 0.0
        assert t_c <= tau * t_p

        prev_ref = None
        for t in range(1, 3 * tau + 8):
            r = gradient_reference_epoch(t, tau)
            assert 1 <= r <= t
            if t <= tau + 1:
                assert r == 1          # fill phase: everything vs w(1)
            else:
                assert r == t - tau    # steady state: staleness == tau
            if prev_ref is not None:
                assert prev_ref <= r <= prev_ref + 1
            prev_ref = r

        times = [master_update_time(t, t_p, t_c) for t in range(1, 9)]
        recvs = [worker_receives_update_at(t, t_p, t_c)
                 for t in range(1, 9)]
        for t, (m, w) in enumerate(zip(times, recvs), start=1):
            assert m <= w                     # update before broadcast
            if t_c > 0:
                assert m < w
        assert all(b > a for a, b in zip(times, times[1:]))
        assert all(b > a for a, b in zip(recvs, recvs[1:]))

        tl = Timeline(t_p=t_p, t_c=t_c)
        for t in range(1, 9):
            # halfway between updates t and t+1, exactly t updates done
            # (mid-interval probe: exact update instants sit on float
            # boundaries where // would be precision-dependent)
            probe = master_update_time(t, t_p, t_c) + 0.5 * t_p
            assert tl.epochs_until(probe, "ambdg") == t


def test_staleness_rejects_bad_tp():
    with pytest.raises(ValueError):
        staleness(1.0, 0.0)
    with pytest.raises(ValueError):
        gradient_reference_epoch(0, 2)


def test_staleness_rejects_negative_tc():
    """These helpers used to silently accept T_c < 0 and hand back a
    negative tau — which then indexed delay rings backwards."""
    with pytest.raises(ValueError, match="non-negative"):
        staleness(-1.0, 2.5)
    with pytest.raises(ValueError, match="non-negative"):
        Timeline(t_p=2.5, t_c=-0.5).tau


def test_reference_epoch_rejects_non_integer_epochs():
    """...and non-integer epoch floats, returning fractional epochs
    (r = t - tau on t=2.5). Integral floats from timeline algebra
    (2.0) stay accepted; 2.5, booleans and non-numbers do not."""
    assert gradient_reference_epoch(5.0, 3) == 2     # integral float ok
    assert gradient_reference_epoch(5, 3.0) == 2
    with pytest.raises(ValueError, match="integral"):
        gradient_reference_epoch(2.5, 3)
    with pytest.raises(ValueError, match="integral"):
        gradient_reference_epoch(5, 1.5)
    with pytest.raises(ValueError, match="integer"):
        gradient_reference_epoch(True, 1)
    with pytest.raises(ValueError, match="integer"):
        gradient_reference_epoch("3", 1)
    with pytest.raises(ValueError):
        gradient_reference_epoch(3, -1)              # negative tau


def test_variable_delay_algebra():
    """The stochastic-tau timeline helpers: reference sequence is the
    per-step downlink model, delivery_schedule the uplink/ring model,
    observed_staleness its per-step mean."""
    from repro.core.staleness import (delivery_schedule,
                                      observed_staleness,
                                      reference_epoch_sequence)
    delays = [2, 1, 3, 1, 1]
    # downlink: ref_t = max(1, t - tau_t)
    assert reference_epoch_sequence(delays) == [1, 1, 1, 3, 4]
    # constant sequence reduces to gradient_reference_epoch
    assert reference_epoch_sequence([2] * 6) == [
        gradient_reference_epoch(t, 2) for t in range(1, 7)]
    # uplink: push s lands at s + tau_s; step 4 collects pushes 2 (1+
    # delay 2... no: push 1 + delay 2 -> 3; push 2 + 1 -> 3) etc.
    sched = delivery_schedule(delays)
    assert sched == {3: [1, 2], 5: [4], 6: [3, 5]}
    # per-step mean staleness over the delivered pushes
    assert observed_staleness(delays, 6) == [
        0.0, 0.0, 1.5, 0.0, 1.0, 2.0]
    with pytest.raises(ValueError):
        delivery_schedule([1, -2])
    with pytest.raises(ValueError):
        delivery_schedule([1, 2.5])


def test_staleness_property_sweep_variable():
    """Seeded random delay sequences: delivery_schedule partitions the
    push steps exactly once (conservation), every delivered step obeys
    the emitted delay, and observed_staleness averages it."""
    rng = np.random.default_rng(1)
    for _ in range(50):
        n = int(rng.integers(5, 40))
        delays = rng.integers(0, 9, size=n).tolist()
        from repro.core.staleness import (delivery_schedule,
                                          observed_staleness)
        sched = delivery_schedule(delays)
        seen = sorted(s for ss in sched.values() for s in ss)
        assert seen == list(range(1, n + 1))        # each push once
        for u, pushes in sched.items():
            for s in pushes:
                assert u - s == delays[s - 1]       # staleness == tau_s
        obs = observed_staleness(delays, n + 10)
        for u, pushes in sched.items():
            if u <= n + 10:
                expect = sum(u - s for s in pushes) / len(pushes)
                assert obs[u - 1] == pytest.approx(expect)


# ---------------------------------------------------------------------------
# zero-arrival staleness contract of the delay-adaptive step (PR 7 fix)
# ---------------------------------------------------------------------------
def _ambdg_variable_run(delays, seed=0):
    """Run the full ambdg strategy (adaptive alpha, linreg) under an
    explicit per-step delay sequence; return the per-step metrics."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    import repro.api as api
    from repro.configs.base import (AmbdgConfig, DelayConfig, LINREG,
                                    MeshConfig, ModelConfig, RunConfig,
                                    TRAIN_4K)
    from repro.models import build_model

    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0,
                      d_model=0, n_heads=0, n_kv_heads=0, d_ff=0,
                      vocab_size=0, linreg_dim=24)
    batch = 8
    rc = RunConfig(
        model=cfg,
        shape=dataclasses.replace(TRAIN_4K, seq_len=0,
                                  global_batch=batch),
        mesh=MeshConfig(n_pods=1, data=1, model=1),
        ambdg=AmbdgConfig(tau=2, n_microbatches=2, b_bar=float(batch),
                          smoothness_L=1.0),
        strategy="ambdg",
        delay=DelayConfig(process="jitter", tau_max=4, seed=7,
                          adaptive_alpha=True))
    model = build_model(cfg)
    s = api.build(model, rc)
    state = s.init_state(jax.random.PRNGKey(seed))
    step = jax.jit(s.train_step, donate_argnums=(0,))
    ms = []
    for t, d in enumerate(delays):
        b = model.dummy_batch(batch, key=jax.random.PRNGKey(1000 + t))
        state, m = step(state, dict(b, delay=jnp.int32(d)))
        ms.append({k: float(v) for k, v in m.items()})
    return ms, rc


def test_zero_arrival_metrics_report_fallback_staleness():
    """Unit test on the metrics dict: a stall step reports the ring-cap
    FALLBACK staleness in tau_applied (the value the step size used),
    never 0, and applied_count == 0 is the zero-arrival signal."""
    # delays [0,0,0,4,4,4,0,...]: pushes 4-6 land at steps 7-9, so
    # steps 3-5 (0-indexed) pop nothing
    delays = [0, 0, 0, 4, 4, 4, 0, 0, 0, 0]
    ms, rc = _ambdg_variable_run(delays)
    tau_max = rc.delay.tau_max
    stall_steps = [3, 4, 5]
    for t, m in enumerate(ms):
        if t in stall_steps:
            assert m["applied_count"] == 0.0, (t, m)
            assert m["tau_applied"] == float(tau_max), (t, m)
        else:
            assert m["applied_count"] > 0.0, (t, m)
            assert 0.0 <= m["tau_applied"] <= float(tau_max)


def test_observed_staleness_fallback_matches_device_tau_applied():
    """Host/device parity for the zero-arrival fallback: the pure
    algebra helper with ``empty_fallback=tau_max`` reproduces the
    device ring's ``metrics["tau_applied"]`` step for step — stall
    steps report the ring cap on BOTH sides (the helper's default 0.0
    used to disagree with the device exactly there, so a host-side
    delay-adaptive consumer would run a larger alpha than the device
    on every stall). Constant per-push counts make the device's
    count-weighted mean equal the helper's per-push mean."""
    from repro.core.staleness import observed_staleness

    delays = [0, 0, 0, 4, 4, 4, 0, 0, 0, 0]
    ms, rc = _ambdg_variable_run(delays)
    tau_max = rc.delay.tau_max
    expect = observed_staleness(delays, len(delays),
                                empty_fallback=float(tau_max))
    got = [m["tau_applied"] for m in ms]
    assert got == pytest.approx(expect), (got, expect)
    # ... while the raw-algebra default still reports 0.0 on stalls
    raw = observed_staleness(delays, len(delays))
    assert [raw[t] for t in (3, 4, 5)] == [0.0, 0.0, 0.0]
    assert [e for t, e in enumerate(expect) if t not in (3, 4, 5)] == \
        [r for t, r in enumerate(raw) if t not in (3, 4, 5)]


def test_zero_arrival_alpha_never_exceeds_arrival_alpha():
    """Seeded regression for the zero-arrival step-size contract: a
    burst of zero-arrival steps must never yield a LARGER alpha than
    the same steps with arrivals (alpha is decreasing in tau; the old
    bug fed tau_obs = 0 on stalls, claiming a stalled network was
    perfectly fresh). Both runs see the same batches and the same
    step indices t, so alpha(t, tau_applied) is comparable per step."""
    from repro.core import dual_averaging as da

    delays_burst = [0, 0, 4, 4, 4, 4, 0, 0, 0, 0]   # stalls at 2-5
    delays_fresh = [0] * len(delays_burst)          # arrivals every step
    ms_burst, rc = _ambdg_variable_run(delays_burst)
    ms_fresh, _ = _ambdg_variable_run(delays_fresh)
    stalled = [t for t, m in enumerate(ms_burst)
               if m["applied_count"] == 0.0]
    assert stalled == [2, 3, 4, 5]
    for t, (mb, mf) in enumerate(zip(ms_burst, ms_fresh)):
        # t increments every step in both runs -> same first argument
        a_burst = float(da.alpha(float(t + 2), rc.ambdg,
                                 tau=mb["tau_applied"]))
        a_fresh = float(da.alpha(float(t + 2), rc.ambdg,
                                 tau=mf["tau_applied"]))
        assert a_burst <= a_fresh + 1e-12, (t, mb, mf)
        if t in stalled:
            assert a_burst < a_fresh     # strictly smaller on stalls
