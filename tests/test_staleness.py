"""Staleness timeline tests against the paper's worked example (Fig. 1)."""
import pytest

from repro.core.staleness import (Timeline, gradient_reference_epoch,
                                  staleness)


def test_tau_definition():
    assert staleness(10.0, 2.5) == 4
    assert staleness(7.5, 2.5) == 3
    assert staleness(8.0, 2.5) == 4      # ceil
    assert staleness(0.0, 2.5) == 0


def test_paper_fig1_example():
    """T_c = 3 T_p => tau = 3; gradients for epochs 1..4 use w(1);
    the master's 6th update uses gradients w.r.t. w(2) (staleness 3)."""
    tau = staleness(7.5, 2.5)
    assert tau == 3
    for t in (1, 2, 3, 4):
        assert gradient_reference_epoch(t, tau) == 1
    assert gradient_reference_epoch(5, tau) == 2   # w(6) <- grads at w(2)
    assert gradient_reference_epoch(9, tau) == 6


def test_update_times():
    tl = Timeline(t_p=2.5, t_c=10.0)
    assert tl.tau == 4
    # paper Sec. VI-A: AMB-DG updates every T_p = 2.5 s, first at 7.5 s;
    # AMB every T_p + T_c = 12.5 s
    assert tl.epochs_until(7.5, "ambdg") == 1
    assert tl.epochs_until(9.9, "ambdg") == 1
    assert tl.epochs_until(10.0, "ambdg") == 2
    assert tl.epochs_until(7.5, "amb") == 1
    assert tl.epochs_until(19.9, "amb") == 1
    assert tl.epochs_until(20.0, "amb") == 2


def test_epoch_durations_converge_when_tc_zero():
    """As T_c -> 0, AMB-DG reduces to AMB (paper Sec. VI-A.4)."""
    tl = Timeline(t_p=2.5, t_c=0.0)
    assert tl.tau == 0
    assert tl.epochs_until(25.0, "ambdg") == tl.epochs_until(25.0, "amb")
