"""Strategy-conformance suite: every registered strategy runs through
ONE contract — init/step shapes, checkpoint roundtrip, jit with
donation, the tau=0 AMB == AMB-DG bit-equality — plus the
decentralized-vs-dense-oracle bit-exactness on 8 virtual devices
(in-process when the CI leg forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, in a
subprocess otherwise so the forced device count never leaks).

``REPRO_TEST_STRATEGY=<name>`` narrows the per-strategy tests to one
strategy (the CI decentralized leg).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro.configs.base import (AmbdgConfig, ConsensusConfig, LINREG,
                                MeshConfig, ModelConfig, RunConfig,
                                TRAIN_4K)
from repro.core import consensus
from repro.models import build_model
from repro.train import checkpoint as ckpt

CFG = ModelConfig(name="linreg", family=LINREG, n_layers=0, d_model=0,
                  n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
                  linreg_dim=48)
BATCH = 16
N_WORKERS = 4

_only = os.environ.get("REPRO_TEST_STRATEGY")
STRATEGIES = ((_only,) if _only else api.available_strategies())
# the CI gossip-compression matrix leg runs the whole per-strategy
# contract (shapes, donation, checkpoint roundtrip, oracle harness)
# under each gossip compression mode
_GOSSIP_COMPRESSION = os.environ.get("REPRO_TEST_GOSSIP_COMPRESSION",
                                     "none")


def make_rc(strategy: str, **ambdg_kw) -> RunConfig:
    kw = dict(tau=2, n_microbatches=2, b_bar=float(BATCH),
              smoothness_L=1.0)
    kw.update(ambdg_kw)
    return RunConfig(
        model=CFG,
        shape=dataclasses.replace(TRAIN_4K, seq_len=0, global_batch=BATCH),
        mesh=MeshConfig(n_pods=1, data=1, model=1),
        ambdg=AmbdgConfig(**kw),
        strategy=strategy,
        consensus=ConsensusConfig(topology="ring", n_workers=N_WORKERS,
                                  compression=_GOSSIP_COMPRESSION))


@pytest.fixture(scope="module")
def model():
    return build_model(CFG)


def batches(n, start=0):
    m = build_model(CFG)
    return [m.dummy_batch(BATCH, key=jax.random.PRNGKey(1000 + t))
            for t in range(start, start + n)]


def test_registry_names():
    assert set(api.available_strategies()) >= {
        "amb", "ambdg", "kbatch", "decentralized"}
    with pytest.raises(ValueError, match="unknown strategy"):
        api.get_strategy("nope")


# ---------------------------------------------------------------------------
# the contract, per strategy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", STRATEGIES)
def test_init_and_step_shapes(model, name):
    s = api.build(model, make_rc(name))
    state = s.init_state(jax.random.PRNGKey(0))
    out_state, metrics = s.train_step(state, batches(1)[0])
    # metrics contract: the loop float()-casts every entry
    assert {"loss", "applied_count", "local_count",
            "step"} <= set(metrics)
    for v in metrics.values():
        assert jnp.shape(v) == ()
    # array leaves keep shapes/dtypes across steps (static aux like the
    # arena's slot phase MAY advance, so compare leaves, not treedefs)
    lin, lout = jax.tree.leaves(state), jax.tree.leaves(out_state)
    assert len(lin) == len(lout)
    for a, b in zip(lin, lout):
        assert a.shape == b.shape and a.dtype == b.dtype
    # schedule probes respond
    sched = s.staleness_schedule()
    assert sched.kind in ("delayed", "sync", "random", "gossip")
    tm = type(s).timeline_model()
    assert tm.scheme == name
    if not tm.event_driven:
        assert tm.update_time(1, 2.5, 10.0) > 0


@pytest.mark.parametrize("name", STRATEGIES)
def test_jit_with_donation(model, name):
    s = api.build(model, make_rc(name))
    step = jax.jit(s.train_step, donate_argnums=(0,))
    state = s.init_state(jax.random.PRNGKey(0))
    for b in batches(3):
        state, metrics = step(state, b)
    assert int(metrics["step"]) == 3


@pytest.mark.parametrize("name", STRATEGIES)
def test_checkpoint_roundtrip(model, name, tmp_path):
    s = api.build(model, make_rc(name))
    step = jax.jit(s.train_step, donate_argnums=(0,))
    state = s.init_state(jax.random.PRNGKey(0))
    for b in batches(3):
        state, _ = step(state, b)
    ckpt.save(str(tmp_path), 3, state, extra={"step": 3})
    template = s.init_state(jax.random.PRNGKey(1))
    restored, extra = ckpt.restore(str(tmp_path), template)
    assert extra["step"] == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # both continue bit-for-bit
    for b in batches(2, start=3):
        state, _ = step(state, b)
        restored, _ = step(restored, b)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_amb_is_tau0_ambdg_bitwise(model):
    """The synchronous baseline IS the AMB-DG step at tau=0 — bit for
    bit, as the module docstrings promise."""
    amb = api.build(model, make_rc("amb"))
    dg0 = api.build(model, make_rc("ambdg", tau=0))
    sa = amb.init_state(jax.random.PRNGKey(0))
    sd = dg0.init_state(jax.random.PRNGKey(0))
    step_a = jax.jit(amb.train_step, donate_argnums=(0,))
    step_d = jax.jit(dg0.train_step, donate_argnums=(0,))
    for b in batches(4):
        sa, ma = step_a(sa, b)
        sd, md = step_d(sd, b)
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sd)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(ma["loss"]) == float(md["loss"])
    assert amb.staleness_schedule().tau == 0


@pytest.mark.parametrize("compression", ["none", "int8"])
def test_fixed_delay_process_is_static_path_bitwise(model, compression):
    """rc.delay defaults to the 'fixed' process, which must BE the
    pre-delay-process static-phase v2 master path — explicit fixed
    config, default config, and the delay-tolerant ring fed the
    constant sequence all produce bit-identical states per step
    (params, dual z, int8 ring + residual). The first two share the
    code path (pinning that adding rc.delay changed nothing); the
    third pins the degeneracy of the new ring."""
    from repro.configs.base import DelayConfig
    tau = 2
    rc_default = make_rc("ambdg", tau=tau, pod_compression=compression)
    rc_fixed = rc_default.replace(
        delay=DelayConfig(process="fixed", tau_max=tau))
    # constant "jitter" with width 0 emits tau every step: the
    # delay-tolerant ring on the same sequence the static path encodes
    rc_const = rc_default.replace(
        delay=DelayConfig(process="jitter", tau_max=tau, jitter=0,
                          delay_min=tau))
    runs = {}
    for name, rc in (("default", rc_default), ("fixed", rc_fixed),
                     ("const", rc_const)):
        s = api.build(model, rc)
        state = s.init_state(jax.random.PRNGKey(0))
        step = jax.jit(s.train_step, donate_argnums=(0,))
        for b in batches(3 * (tau + 1)):
            if name == "const":
                b = dict(b, delay=jnp.int32(tau))
            state, m = step(state, b)
        runs[name] = (state, m)
    base_state, base_m = runs["default"]
    for name in ("fixed", "const"):
        state, m = runs[name]
        np.testing.assert_array_equal(
            np.asarray(state.params["w"]),
            np.asarray(base_state.params["w"]), err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(state.opt_state.z),
            np.asarray(base_state.opt_state.z), err_msg=name)
        # per-SLOT compare: the variable runs carry a stacked (v3)
        # ring, the default a v2 tuple — both index slots on axis 0
        for a, b_ in zip(list(state.arena.ring),
                         list(base_state.arena.ring)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_),
                                          err_msg=name)
        if compression == "int8":
            np.testing.assert_array_equal(
                np.asarray(state.arena.residual),
                np.asarray(base_state.arena.residual), err_msg=name)
        assert float(m["loss"]) == float(base_m["loss"])
        assert float(m["applied_count"]) == float(base_m["applied_count"])
    assert float(runs["const"][1]["tau_applied"]) == float(tau)


def test_stochastic_delay_strategy_contract(model):
    """A genuinely stochastic process through the full Strategy
    surface: jit + donation, scalar metrics incl. tau_applied within
    bounds, checkpoint roundtrip continuing bit-for-bit (the ring's
    due/stale metadata must survive restore)."""
    from repro.configs.base import DelayConfig
    from repro.core.delay_process import make_delay_process
    rc = make_rc("ambdg", tau=2, pod_compression="int8")
    rc = rc.replace(delay=DelayConfig(process="heavy_tail", tau_max=4,
                                      seed=9))
    s = api.build(model, rc)
    sched = s.staleness_schedule()
    assert sched.kind == "random" and sched.tau == 4
    dp = make_delay_process(rc.delay, rc.ambdg.tau)
    state = s.init_state(jax.random.PRNGKey(0))
    step = jax.jit(s.train_step, donate_argnums=(0,))
    delays = dp.sequence(8)
    for i, b in enumerate(batches(4)):
        state, m = step(state, dict(b, delay=jnp.int32(delays[i])))
        assert 0.0 <= float(m["tau_applied"]) <= 4.0
        for v in m.values():
            assert jnp.shape(v) == ()
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 4, state, extra={"step": 4})
        restored, _ = ckpt.restore(d, s.init_state(jax.random.PRNGKey(1)))
    for a, b_ in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    for i, b in enumerate(batches(3, start=4)):
        bd = dict(b, delay=jnp.int32(delays[4 + i]))
        state, _ = step(state, bd)
        restored, _ = step(restored, bd)
    for a, b_ in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_variable_ring_tuple_checkpoint_migrates(model, tmp_path):
    """Delay-tolerant checkpoints saved under the per-slot tuple
    layout (pre stacked-v3) restore transparently: slot k of the
    tuple is row k of the stack, so ``_migrate_variable_ring_v2``
    re-stacks the ring (and int8 scales) and the run continues
    bit-for-bit — the same compatibility contract as the ring-v1 and
    pre-residual migrations."""
    from repro.configs.base import DelayConfig
    from repro.core.delay_process import make_delay_process
    rc = make_rc("ambdg", tau=2, pod_compression="int8")
    rc = rc.replace(delay=DelayConfig(process="jitter", tau_max=4,
                                      seed=11))
    s = api.build(model, rc)
    dp = make_delay_process(rc.delay, rc.ambdg.tau)
    state = s.init_state(jax.random.PRNGKey(0))
    step = jax.jit(s.train_step, donate_argnums=(0,))
    delays = dp.sequence(8)
    for i, b in enumerate(batches(4)):
        state, _ = step(state, dict(b, delay=jnp.int32(delays[i])))
    ckpt.save(str(tmp_path), 4, state, extra={"step": 4})
    # rewrite the archive in the old per-slot tuple layout
    path = os.path.join(str(tmp_path), "step_000000004", "state.npz")
    data = dict(np.load(path))
    ring_keys = [k for k in data if k.endswith(".ring")]
    assert ring_keys, sorted(data)
    old = {}
    for k, v in data.items():
        if k.endswith(".ring") or k.endswith(".scales"):
            for j in range(v.shape[0]):
                old[f"{k}/{j}"] = v[j]
        else:
            old[k] = v
    np.savez(path, **old)
    restored, extra = ckpt.restore(str(tmp_path),
                                   s.init_state(jax.random.PRNGKey(1)))
    assert extra["step"] == 4
    for a, b_ in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    for i, b in enumerate(batches(3, start=4)):
        bd = dict(b, delay=jnp.int32(delays[4 + i]))
        state, _ = step(state, bd)
        restored, _ = step(restored, bd)
    for a, b_ in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_delay_process_strategy_validation(model):
    """rc.delay threads through every strategy: ambdg runs it, kbatch
    accepts it (the event-driven simulator consumes it through
    ``api.simulate(strategy_instance, ...)``), amb and decentralized
    reject it with a pointed error."""
    from repro.configs.base import DelayConfig
    stoch = DelayConfig(process="bursty", tau_max=4, seed=3)
    for name in ("amb", "decentralized"):
        with pytest.raises(ValueError, match="delay process"):
            api.build(model, make_rc(name).replace(delay=stoch))
    kb = api.build(model, make_rc("kbatch").replace(delay=stoch))
    assert "bursty" in kb.staleness_schedule().description
    # the on-device kbatch step stays the sync degenerate...
    state = kb.init_state(jax.random.PRNGKey(0))
    state, m = kb.train_step(state, batches(1)[0])
    assert int(m["staleness"]) == 0
    # ...but the knob is NOT inert: the strategy reconstructs its
    # seeded process (nominal tau preserved through the tau=0 strip)
    dp = kb.delay_process()
    assert dp is not None and dp.name == "bursty" and dp.tau == 2
    assert api.build(model, make_rc("kbatch")).delay_process() is None
    # pytree master path has no delay-tolerant ring
    with pytest.raises(ValueError, match="arena"):
        api.build(model, make_rc("ambdg").replace(
            delay=stoch, master_impl="pytree"))


def test_simulate_wires_strategy_delay_process():
    """api.simulate given a BUILT strategy instance feeds rc.delay's
    seeded process into the simulator engine — per-message uplink
    jitter for kbatch (t_p defaulted from the config), per-epoch
    staleness for ambdg — and stays delay-free for fixed configs."""
    from repro.configs.base import DelayConfig, ModelConfig
    from repro.data.timing import ShiftedExponential
    from repro.sim import SimProblem
    cfg = ModelConfig(name="linreg", family=LINREG, n_layers=0,
                      d_model=0, n_heads=0, n_kv_heads=0, d_ff=0,
                      vocab_size=0, linreg_dim=16)
    lr_model = build_model(cfg)
    stoch = DelayConfig(process="heavy_tail", tau_max=6, seed=2)
    timing = ShiftedExponential(lam=2 / 3, xi=1.0, b=60)
    problem = lambda: SimProblem(cfg, n_workers=2, seed=7, b_max=64)
    common = dict(t_c=10.0, total_time=25.0, timing=timing)
    for name, kw in (("ambdg", dict(t_p=2.5)),
                     ("kbatch", dict(b_per_msg=16, K=2))):
        rc = RunConfig(model=cfg, shape=dataclasses.replace(
            TRAIN_4K, seq_len=0, global_batch=BATCH),
            mesh=MeshConfig(n_pods=1, data=1, model=1),
            ambdg=AmbdgConfig(tau=2, n_microbatches=2,
                              b_bar=float(BATCH)),
            strategy=name, delay=stoch)
        s = api.build(lr_model, rc)
        tr = api.simulate(s, problem(),
                          opt_cfg=rc.ambdg, **common, **kw)
        assert len(tr.delays) > 0 and max(tr.delays) <= 6, name
        # fixed config: no process reaches the engine
        s0 = api.build(lr_model, rc.replace(delay=DelayConfig()))
        tr0 = api.simulate(s0, problem(),
                           opt_cfg=rc.ambdg, **common, **kw)
        assert tr0.delays == [], name


def test_make_train_step_alias_matches_api(model):
    """The deprecated ``core.make_train_step`` goes through the same
    registry object — one step must agree bit for bit."""
    from repro.core import make_train_step
    rc = make_rc("ambdg")
    init_a, step_a = make_train_step(model, rc)
    s = api.build(model, rc)
    b = batches(1)[0]
    out_a, _ = step_a(init_a(jax.random.PRNGKey(0)), b)
    out_b, _ = s.train_step(s.init_state(jax.random.PRNGKey(0)), b)
    for x, y in zip(jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_timeline_models_pin_paper_algebra():
    """The closed forms the golden sim trace pins (paper Fig. 1)."""
    dg = api.get_strategy("ambdg").timeline_model()
    amb = api.get_strategy("amb").timeline_model()
    kb = api.get_strategy("kbatch").timeline_model()
    t_p, t_c = 2.5, 10.0
    assert dg.update_time(4, t_p, t_c) == 4 * t_p + 0.5 * t_c
    assert dg.epoch_duration(t_p, t_c) == t_p
    assert amb.update_time(4, t_p, t_c) == 4 * t_p + 3.5 * t_c
    assert amb.epoch_duration(t_p, t_c) == t_p + t_c
    assert dg.n_updates(60.0, t_p, t_c) == 22
    assert amb.n_updates(60.0, t_p, t_c) == 5
    assert kb.event_driven and kb.update_time is None


# ---------------------------------------------------------------------------
# kbatch: ref_epoch threading + pop-order-independent staleness
# ---------------------------------------------------------------------------
def test_kbatch_ref_epoch_in_state(model):
    s = api.build(model, make_rc("kbatch"))
    state = s.init_state(jax.random.PRNGKey(0))
    assert int(state.ref_epoch) == 1
    step = jax.jit(s.train_step, donate_argnums=(0,))
    for b in batches(3):
        state, m = step(state, b)
    assert int(state.ref_epoch) == 4
    # synchronous on-device realization: staleness identically 0
    assert int(m["staleness"]) == 0
    assert s.staleness_schedule().kind == "random"


def test_kbatch_master_independent_of_arrival_order():
    """The K-triggering batch is processed in canonical (ref_epoch,
    worker) order: any arrival permutation of the same messages gives
    the identical staleness log AND bit-identical parameters."""
    from repro.core.kbatch import KBatchMaster, Message
    rng = np.random.default_rng(0)
    params = {"w": jnp.zeros((8,), jnp.float32)}
    msgs = [Message(grad_sum={"w": jnp.asarray(
                        rng.standard_normal(8).astype(np.float32))},
                    count=6.0, ref_epoch=1 + (i % 2), worker=i)
            for i in range(4)]
    logs, finals = [], []
    for order in ([0, 1, 2, 3], [3, 1, 0, 2], [2, 3, 1, 0]):
        master = KBatchMaster(params, AmbdgConfig(), K=4)
        for i in order:
            master.receive(msgs[i])
        logs.append(list(master.staleness_log))
        finals.append(np.asarray(master.params["w"]))
    assert logs[0] == logs[1] == logs[2]
    np.testing.assert_array_equal(finals[0], finals[1])
    np.testing.assert_array_equal(finals[0], finals[2])


# ---------------------------------------------------------------------------
# decentralized: stencil == gossip matrix; shard_map == dense oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topology,n", [("ring", 8), ("ring", 2),
                                        ("torus", 4), ("torus", 16),
                                        ("complete", 6)])
def test_stencil_applies_gossip_matrix(topology, n):
    """One stencil-fold round applies exactly the doubly-stochastic
    ``gossip_matrix`` (so the fold IS the matrix-power oracle), and r
    fold rounds track Q^r at float tolerance."""
    np.testing.assert_allclose(consensus._stencil_matrix(topology, n),
                               consensus.gossip_matrix(topology, n),
                               atol=1e-12)
    v = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((n, 16)).astype(np.float32))
    r = 7
    out = consensus.run_consensus_fold(v, topology, r)
    Qr = np.linalg.matrix_power(consensus.gossip_matrix(topology, n), r)
    np.testing.assert_allclose(np.asarray(out), Qr @ np.asarray(v),
                               rtol=1e-5, atol=1e-5)


def test_decentralized_rounds_from_eq24(model):
    rc = make_rc("decentralized")
    s = api.build(model, rc)
    Q = consensus.gossip_matrix("ring", N_WORKERS)
    assert s.rounds == consensus.min_rounds(
        rc.consensus.delta, N_WORKERS, rc.consensus.msg_norm_J,
        consensus.lambda2(Q))
    # explicit override wins
    rc2 = rc.replace(consensus=dataclasses.replace(rc.consensus, rounds=3))
    assert api.build(model, rc2).rounds == 3


def _run_decentralized_oracle_checks():
    """The 8-virtual-device bit-exactness harness: for every topology
    AND every gossip compression mode, run the shard_map strategy
    (ppermute gossip, per-worker duals in arena layout) and re-apply
    the matching dense fold oracle — uncompressed gossip-matrix fold,
    or the compressed fold on the exact in-program (messages, incoming
    residual) — the consensus state AND the error-feedback residual
    must match BIT FOR BIT, every step. Also pins the sharded
    dual-update kernel wrapper against its unsharded twin."""
    assert jax.device_count() >= 8, jax.device_count()
    cfg = dataclasses.replace(CFG, linreg_dim=300)
    model = build_model(cfg)
    batch = 32
    for compression in ("none", "int8"):
        for topology, n in (("ring", 8), ("torus", 4), ("complete", 8)):
            rc = RunConfig(
                model=cfg,
                shape=dataclasses.replace(TRAIN_4K, seq_len=0,
                                          global_batch=batch),
                mesh=MeshConfig(n_pods=1, data=1, model=1),
                ambdg=AmbdgConfig(tau=1, n_microbatches=2,
                                  b_bar=float(batch), proximal="l2_ball",
                                  radius_C=5.0),
                strategy="decentralized",
                consensus=ConsensusConfig(topology=topology, n_workers=n,
                                          gossip_impl="shard_map",
                                          compression=compression,
                                          debug_messages=True))
            s = api.build(model, rc)
            assert s.gossip_impl == "shard_map"
            state = s.init_state(jax.random.PRNGKey(0))
            step = jax.jit(s.train_step)
            if compression == "int8":
                oracle = jax.jit(
                    lambda m0, r0, topology=topology, r=s.rounds:
                    consensus.run_consensus_fold_int8(m0, r0, topology, r))
            else:
                oracle = jax.jit(
                    lambda m0, r0, topology=topology, r=s.rounds:
                    (consensus.run_consensus_fold(m0, topology, r), r0))
            for t in range(3):
                b = model.dummy_batch(batch,
                                      key=jax.random.PRNGKey(50 + t))
                state, m = step(state, b)
                oz, ores = oracle(m["gossip_m0"], m["gossip_r0"])
                tag = f"{compression} {topology} step {t}"
                np.testing.assert_array_equal(
                    np.asarray(state.z), np.asarray(oz), err_msg=tag)
                np.testing.assert_array_equal(
                    np.asarray(state.residual), np.asarray(ores),
                    err_msg=tag)
            if compression == "int8":
                # the residual is live: error feedback actually carries
                # quantization error across steps
                assert float(jnp.max(jnp.abs(state.residual))) > 0.0
            else:
                np.testing.assert_array_equal(
                    np.asarray(state.residual),
                    np.zeros_like(np.asarray(state.residual)))

    # sharded dual-update kernel == unsharded kernel, bit for bit
    # (elementwise; both interpret-mode Pallas on CPU)
    from repro.dist.context import sharding_profile
    from repro.kernels.dual_update.ops import (dual_update_arena,
                                               dual_update_arena_sharded)
    mesh_cfg = MeshConfig(n_pods=2, data=2, model=2)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rows = 512
    z = jax.random.normal(jax.random.PRNGKey(0), (rows, 128))
    g = jax.random.normal(jax.random.PRNGKey(1), (rows, 128))
    count, a = jnp.float32(17.0), jnp.float32(0.03)
    with mesh, sharding_profile(mesh_cfg):
        zs, ws = jax.jit(lambda z, g: dual_update_arena_sharded(
            z, g, count, a, mesh_cfg=mesh_cfg, interpret=True))(z, g)
    zu, wu = jax.jit(lambda z, g: dual_update_arena(
        z, g, count, a, impl="pallas", interpret=True))(z, g)
    np.testing.assert_array_equal(np.asarray(zs), np.asarray(zu))
    np.testing.assert_array_equal(np.asarray(ws), np.asarray(wu))
    print("DECENTRALIZED_ORACLE_OK")


@pytest.mark.slow
def test_decentralized_vs_dense_oracle_8dev():
    """Runs the oracle harness in-process when 8+ devices are already
    forced (the CI decentralized/gossip-compression legs), in a
    subprocess otherwise (hence the ``slow`` marker — the fast tier-1
    CI job deselects it, the dedicated legs cover it in-process)."""
    if jax.device_count() >= 8:
        _run_decentralized_oracle_checks()
        return
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "DECENTRALIZED_ORACLE_OK" in out.stdout


def test_decentralized_dense_fallback_on_one_device(model):
    """auto resolves to the dense fold when n_workers doesn't map onto
    the local devices; the strategy still runs and converges on the
    same contract."""
    s = api.build(model, make_rc("decentralized"))
    if jax.device_count() != N_WORKERS:
        assert s.gossip_impl == "dense"
    state = s.init_state(jax.random.PRNGKey(0))
    step = jax.jit(s.train_step, donate_argnums=(0,))
    for b in batches(3):
        state, m = step(state, b)
    assert np.isfinite(float(m["loss"]))
    assert float(m["consensus_error"]) < 1.0


def test_decentralized_pre_residual_checkpoint_migrates(model, tmp_path):
    """Checkpoints saved before DecentralizedState grew the gossip
    error-feedback ``residual`` restore with a zero overlay (the exact
    state a compression="none" run carries) and continue bit-for-bit
    — the same compatibility contract the ring-v1 migration set.
    Pinned to compression="none": pre-residual checkpoints by
    definition predate the int8 path."""
    rc = make_rc("decentralized")
    rc = rc.replace(consensus=dataclasses.replace(
        rc.consensus, compression="none"))
    s = api.build(model, rc)
    step = jax.jit(s.train_step, donate_argnums=(0,))
    state = s.init_state(jax.random.PRNGKey(0))
    for b in batches(2):
        state, _ = step(state, b)
    ckpt.save(str(tmp_path), 2, state, extra={"step": 2})
    # rewrite the archive as a pre-residual checkpoint
    path = os.path.join(str(tmp_path), "step_000000002", "state.npz")
    data = dict(np.load(path))
    assert ".residual" in data
    old = {k: v for k, v in data.items() if k != ".residual"}
    np.savez(path, **old)
    restored, extra = ckpt.restore(str(tmp_path),
                                   s.init_state(jax.random.PRNGKey(1)))
    assert extra["step"] == 2
    np.testing.assert_array_equal(
        np.asarray(restored.residual),
        np.zeros_like(np.asarray(restored.residual)))
    for b in batches(2, start=2):
        state, _ = step(state, b)
        restored, _ = step(restored, b)
    for a, b_ in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_decentralized_compressed_tracks_uncompressed(model):
    """int8-compressed gossip is a perturbation, not a different
    algorithm: a short run under each compression mode lands on nearby
    losses/parameters, the compressed run carries a live residual
    (and the uncompressed run keeps it identically zero, donated
    through)."""
    states, losses = {}, {}
    for compression in ("none", "int8"):
        rc = make_rc("decentralized")
        rc = rc.replace(consensus=dataclasses.replace(
            rc.consensus, compression=compression))
        s = api.build(model, rc)
        state = s.init_state(jax.random.PRNGKey(0))
        step = jax.jit(s.train_step, donate_argnums=(0,))
        for b in batches(5):
            state, m = step(state, b)
        states[compression], losses[compression] = state, float(m["loss"])
    np.testing.assert_array_equal(
        np.asarray(states["none"].residual),
        np.zeros_like(np.asarray(states["none"].residual)))
    assert float(jnp.max(jnp.abs(states["int8"].residual))) > 0.0
    w_none = np.asarray(states["none"].params["w"])
    w_int8 = np.asarray(states["int8"].params["w"])
    denom = max(float(np.linalg.norm(w_none)), 1e-6)
    assert np.linalg.norm(w_int8 - w_none) / denom < 0.1
    assert abs(losses["int8"] - losses["none"]) <= (
        0.1 * abs(losses["none"]) + 1e-3)


if __name__ == "__main__":
    _run_decentralized_oracle_checks()
